"""Radix-trie prefix registry (PR 9) — trie structure, stable digests,
node-level eviction, txn rollback, and engine-vs-sim parity on the
branching-conversation workload."""
import subprocess
import sys

import pytest

from repro.core.kvcache import (OutOfPagesError, PagedAllocator,
                                RadixPrefixRegistry, attach_prefix_run,
                                chain_keys)
from repro.core.policies import LRUPolicy


# --------------------------------------------------------------------- #
# stable content digests (satellite 1)
# --------------------------------------------------------------------- #

def test_chain_keys_stable_across_processes():
    """Chain keys are blake2b content digests — identical across
    processes and across PYTHONHASHSEED values (builtin ``hash`` is
    salted per process and would shred any persisted/compared chain)."""
    tokens = [3, 1, 4, 1, 5, 9, 2, 6]
    here = chain_keys(tokens, 4)
    prog = ("import sys; sys.path.insert(0, 'src'); "
            "from repro.core.kvcache import chain_keys; "
            f"print(chain_keys({tokens!r}, 4))")
    for seed in ("0", "12345"):
        out = subprocess.run(
            [sys.executable, "-c", prog], capture_output=True, text=True,
            cwd="/root/repo", env={"PYTHONHASHSEED": seed, "PATH": "/usr/bin:/bin"},
            check=True).stdout.strip()
        assert out == str(here), f"PYTHONHASHSEED={seed} changed the chain"
    # chained: a different FIRST page changes every downstream key
    other = chain_keys([9, 9, 9, 9] + tokens[4:], 4)
    assert here[0] != other[0] and here[1] != other[1]


def _tree():
    """A tiny trie: one 3-page chain registered page-by-page (extends
    into a single node), plus helpers to express prompts as chains."""
    reg = RadixPrefixRegistry(LRUPolicy())
    toks = [1, 2, 3, 4, 5, 6]
    keys = chain_keys(toks, 2)
    prev = None
    for i, k in enumerate(keys):
        reg.insert(k, page=10 + i, tokens=toks[2 * i:2 * i + 2],
                   n_kvs=(i + 1) * 2, prev_key=prev)
        prev = k
    return reg, toks, keys


def test_incremental_insert_extends_one_node():
    reg, _, keys = _tree()
    assert len(reg) == 3 and reg.num_nodes == 1
    node = reg.node(keys[0])
    assert node is not None and node.keys == keys
    reg.check_invariants()


def test_longest_prefix_partial_hit_splits_node():
    reg, toks, keys = _tree()
    # a prompt sharing only the first 2 pages: partial hit, node split
    probe_toks = toks[:4] + [7, 8]
    probe = chain_keys(probe_toks, 2)
    assert probe[:2] == keys[:2] and probe[2] != keys[2]
    ptoks = [tuple(probe_toks[i:i + 2]) for i in range(0, 6, 2)]
    pages = reg.lookup_run(probe, ptoks)
    assert pages == [10, 11]                 # longest matching run
    assert reg.num_splits == 1 and reg.num_nodes == 2
    front, tail = reg.node(keys[0]), reg.node(keys[2])
    assert front.keys == keys[:2] and tail.keys == keys[2:]
    assert tail.parent is front
    reg.check_invariants()
    # a full-chain probe still resolves across the split boundary
    full = [tuple(toks[i:i + 2]) for i in range(0, 6, 2)]
    assert reg.lookup_run(keys, full) == [10, 11, 12]


def test_eviction_merges_single_child_back():
    reg, toks, keys = _tree()
    # diverge after page 2 -> split; register the divergent branch
    alt_toks = toks[:4] + [7, 8]
    alt = chain_keys(alt_toks, 2)
    reg.lookup_run(alt, [tuple(alt_toks[i:i + 2]) for i in range(0, 6, 2)])
    reg.insert(alt[2], page=20, tokens=(7, 8), n_kvs=6, prev_key=alt[1])
    assert reg.num_nodes == 3                # front + two tails
    # evicting the divergent leaf leaves ONE child -> path compression
    reg.evict_tail(reg.node(alt[2]))
    assert reg.num_merges == 1 and reg.num_nodes == 1
    merged = reg.node(keys[0])
    assert merged.keys == keys and merged.pages == [10, 11, 12]
    reg.check_invariants()


def test_collision_degrades_to_miss_mid_run():
    """Same chain keys, different claimed tokens (a forged 64-bit
    collision): token re-verification stops the walk at the colliding
    page — the run BEFORE it still attaches."""
    reg, toks, keys = _tree()
    lying = [tuple(toks[0:2]), (9, 9), tuple(toks[4:6])]
    assert reg.lookup_run(keys, lying) == [10]
    assert reg.get(keys[1], tokens=(9, 9)) is None
    assert reg.get(keys[1], tokens=toks[2:4]) == 11
    reg.check_invariants()


def test_insert_duplicate_key_rejected():
    reg, _, keys = _tree()
    with pytest.raises(ValueError, match="already registered"):
        reg.insert(keys[1], page=99, tokens=(1, 2), n_kvs=4)


# --------------------------------------------------------------------- #
# node refcounts + leaf/tail-first eviction (allocator level)
# --------------------------------------------------------------------- #

def _branching_alloc(num_pages=8, pg=2):
    """Allocator whose registry holds a branching tree: shared 2-page
    front, two 1-page tails."""
    a = PagedAllocator(num_pages=num_pages, page_size=pg)
    left = [1, 2, 3, 4, 5, 6]
    right = [1, 2, 3, 4, 7, 8]
    kl, kr = chain_keys(left, pg), chain_keys(right, pg)
    a.allocate(0, 6)
    a.register_prefix(0, kl, [left[i:i + pg] for i in range(0, 6, pg)])
    a.free(0)
    a.allocate(1, 6)
    # front 2 pages hit the cached run; only the tail registers anew
    pages = a.lookup_prefix(kr, [right[i:i + pg] for i in range(0, 6, pg)])
    assert len(pages) == 2
    a.free(1)
    a.allocate(2, 6)
    a.register_prefix(2, kr, [right[i:i + pg] for i in range(0, 6, pg)])
    a.free(2)
    return a, kl, kr


def test_node_refs_derived_from_tables():
    a, kl, kr = _branching_alloc()
    reg = a.prefix_cache
    front = reg.node(kl[0])
    assert reg.node_refs(front) == 0         # pin-only
    pages = a.lookup_prefix(kl)
    a.share(5, pages[:1], 2)
    assert reg.node_refs(front) == 1         # one table mapping
    a.free(5)
    assert reg.node_refs(front) == 0


def test_leaf_first_tail_first_eviction_order():
    """Pressure evicts LEAF tails before any interior page: an evicted
    node never strands live descendants, and along each chain pages go
    deepest-first (residency stays prefix-closed)."""
    a, kl, kr = _branching_alloc(num_pages=8, pg=2)
    evicted = []
    a.on_evict = lambda key, page, tokens, n_kvs: evicted.append(key)
    assert len(a.prefix_cache) == 4 and a.free_pages == 4
    a.allocate(7, 12)                        # 6 pages: evicts 2 of 4
    leaf_keys = {kl[2], kr[2]}
    assert set(evicted) == leaf_keys         # both leaves, no interior
    front = a.prefix_cache.node(kl[0])
    assert front is not None and front.keys == kl[:2]
    a.check_invariants()
    a.free(7)
    evicted.clear()
    a.allocate(8, 16)                        # full pool: front goes too
    assert evicted == [kl[1], kl[0]]         # tail-first along the chain
    assert len(a.prefix_cache) == 0
    a.check_invariants()


def test_exact_mode_attach_is_all_or_nothing():
    a, kl, kr = _branching_alloc()
    toks = [(1, 2), (3, 4), (5, 6)]
    # trie mode: a probe missing its last page still attaches the front
    probe_toks = [(1, 2), (3, 4), (9, 9)]
    probe = chain_keys([1, 2, 3, 4, 9, 9], 2)
    att, prom = attach_prefix_run(a, 6, probe, probe_toks)
    assert (att, prom) == (4, 0) and a.table(6).num_tokens == 4
    a.free(6)
    # exact mode: same partial probe attaches NOTHING...
    att, prom = attach_prefix_run(a, 6, probe, probe_toks, exact=True)
    assert (att, prom) == (0, 0) and not a.has(6)
    # ...but a fully-resident chain still attaches whole
    att, prom = attach_prefix_run(a, 6, kl, toks, exact=True)
    assert (att, prom) == (6, 0) and a.table(6).num_tokens == 6
    a.free(6)
    a.check_invariants()


# --------------------------------------------------------------------- #
# txn rollback: the trie is a snapshot participant
# --------------------------------------------------------------------- #

def test_txn_rollback_restores_trie_structure():
    from repro.serving.txn import snapshot_allocator

    a, kl, kr = _branching_alloc()
    reg = a.prefix_cache
    before = reg.snapshot_state()
    restore = snapshot_allocator(a)
    # mutate through every structural path: split (partial probe),
    # insert, tail eviction + merge
    probe_toks = [(1, 2), (9, 9)]
    probe = chain_keys([1, 2, 9, 9], 2)
    reg.lookup_run(probe, probe_toks)        # diverges mid-front: split
    assert reg.num_splits == 2 and reg.num_nodes == 4
    a.allocate(3, 8)                         # absorbs the free pages
    a.allocate(4, 4)                         # evicts both leaf tails
    assert reg.num_merges >= 1
    restore()
    assert reg.snapshot_state() == before
    a.check_invariants()
    order_after = reg.eviction_order()
    assert set(order_after) == {kl[0], kl[2], kr[2]}
    # post-rollback the registry still serves and still evicts cleanly
    assert a.lookup_prefix(kl) != []
    a.allocate(5, 16)
    assert len(reg) == 0
    a.check_invariants()


# --------------------------------------------------------------------- #
# engine vs simulator parity on conversation_tree (satellite 3)
# --------------------------------------------------------------------- #

def _parity(spec):
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core import (PrefixTierSim, TheoreticalCostModel,
                            get_hardware, make_scheduler, simulate)
    from repro.data.workloads import conversation_tree
    from repro.models import model as M
    from repro.serving import Engine, EngineConfig

    cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                              dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    cm = TheoreticalCostModel(cfg, get_hardware("tpu_v5e"))

    def workload():
        return conversation_tree(n=12, page_size=8, vocab=cfg.vocab_size)

    sched = make_scheduler("vllm", 256, S=512, replacement="srf",
                           cache_policy="break_even", cache_demotion=True,
                           cost_model=cm)
    eng = Engine(cfg, params, sched,
                 EngineConfig(nslots=4, cache_len=64, chunk=16,
                              plane="paged", page_size=8,
                              cache_policy="break_even",
                              cache_demotion=True, faults=spec),
                 cost_model=cm)
    res = eng.run(workload())

    sched2 = make_scheduler("vllm", 256, S=512, replacement="srf",
                            page_size=8, cache_policy="break_even",
                            cache_demotion=True)
    sched2.cfg.max_running = 4
    sched2.cfg.faults = spec
    nbytes = 2 * cfg.num_layers * 8 * cfg.num_kv_heads * cfg.head_dim_ \
        * jnp.dtype(cfg.dtype).itemsize
    shadow = PrefixTierSim(sched2.cfg, cm, page_nbytes=nbytes)
    sim = simulate(sched2, workload(), cm, prefix_sim=shadow)

    assert res.swap_stats["trie_hits"] > 0
    assert res.swap_stats["partial_hit_tokens"] > 0
    for key in ("trie_hits", "partial_hit_tokens", "demotions",
                "promotions", "demote_drops", "prefix_integrity"):
        assert sim.prefix_stats[key] == res.swap_stats[key], key
    for key in ("prefix_hits", "prefix_shared_tokens", "reclaimed"):
        assert sim.prefix_stats[key] == eng.allocator.stats[key], key
    assert sim.makespan == pytest.approx(res.metrics.makespan, rel=1e-9)
    eng_swaps = [b.swap_s for b in res.metrics.batches]
    sim_swaps = [b.swap_s for b in sim.batches]
    assert eng_swaps == pytest.approx(sim_swaps, rel=1e-9)


def test_sim_engine_parity_conversation_tree():
    _parity(None)


def test_sim_engine_parity_conversation_tree_under_faults():
    from repro.serving.faults import FaultSpec
    _parity(FaultSpec(seed=5, p_store_transient=0.3, p_corrupt=0.3,
                      p_demote_fail=0.3, p_promote_fail=0.3))
