"""Algorithm-1 semantics: memory safety, policy behaviour, preset taxonomy."""
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.policies import select_victim
from repro.core.request import Phase, Request
from repro.core.scheduler import Scheduler, SchedulerConfig, make_scheduler


def mk_requests(spec):
    return [Request(rid=i, input_len=I, output_len=O, arrival=a)
            for i, (I, O, a) in enumerate(spec)]


def run_to_completion(sched, requests, max_batches=50_000):
    for r in requests:
        sched.add_request(r)
    t = 0.0
    mems = []
    for _ in range(max_batches):
        if not sched.has_work():
            return mems
        batch = sched.get_next_batch()
        assert batch.items, "deadlock"
        t += 1.0
        # memory constraint: total held KVs after the batch <= M
        for r, c in batch.items:
            r.advance(c, t)
            if r.finished:
                sched.complete(r)
        held = sum(r.m for r in sched.running)
        mems.append(held)
        assert held <= sched.cfg.M, (held, sched.cfg.M)
    raise AssertionError("did not converge")


def test_memory_never_exceeded_under_pressure():
    sched = make_scheduler("vllm", M=40, S=128)
    run_to_completion(sched, mk_requests([(8, 8, 0.0)] * 12))
    assert sched.num_preemptions > 0  # pressure actually happened


def test_pf_never_preempts():
    sched = make_scheduler("vllm_pf", M=40, S=128)
    run_to_completion(sched, mk_requests([(8, 8, 0.0)] * 12))
    assert sched.num_preemptions == 0


def test_orca_reserves_context():
    sched = make_scheduler("orca", M=40, S=16)
    # S=16 reservation => only 2 concurrent requests
    reqs = mk_requests([(4, 4, 0.0)] * 6)
    for r in reqs:
        sched.add_request(r)
    batch = sched.get_next_batch()
    assert len(batch) == 2


def test_chunked_prefill_respects_token_budget():
    cfg = SchedulerConfig(M=10_000, C=16, S=4096, priority="decode_first",
                          hybrid=True, chunked=True)
    sched = Scheduler(cfg)
    r = Request(rid=0, input_len=100, output_len=2)
    sched.add_request(r)
    batch = sched.get_next_batch()
    assert batch.items[0][1] == 16          # cropped to C
    assert batch.total_tokens <= 16


def test_nonchunked_skips_oversized_prefill():
    cfg = SchedulerConfig(M=10_000, C=16, S=4096, chunked=False)
    sched = Scheduler(cfg)
    sched.add_request(Request(rid=0, input_len=100, output_len=2))
    sched.add_request(Request(rid=1, input_len=8, output_len=2))
    batch = sched.get_next_batch()
    assert [r.rid for r in batch.requests] == [1]


def test_hybrid_batching_mixes_phases():
    cfg = SchedulerConfig(M=1000, C=4096, S=4096, priority="decode_first",
                          hybrid=True)
    sched = Scheduler(cfg)
    r0 = Request(rid=0, input_len=4, output_len=4)
    sched.add_request(r0)
    b = sched.get_next_batch()
    r0.advance(4, 1.0)                      # r0 is now a decode
    sched.add_request(Request(rid=1, input_len=4, output_len=2))
    b = sched.get_next_batch()
    phases = sorted(r.phase.value for r in b.requests)
    assert phases == ["decode", "prefill"]


def test_nonhybrid_single_phase():
    cfg = SchedulerConfig(M=1000, C=4096, S=4096, priority="prefill_first",
                          hybrid=False)
    sched = Scheduler(cfg)
    r0 = Request(rid=0, input_len=4, output_len=4)
    sched.add_request(r0)
    sched.get_next_batch()
    r0.advance(4, 1.0)
    sched.add_request(Request(rid=1, input_len=4, output_len=2))
    b = sched.get_next_batch()
    assert len({r.phase for r in b.requests}) == 1


def test_srf_preempts_smallest_m():
    """SRF keeps long (large-m) requests resident (paper §8)."""
    cfg = SchedulerConfig(M=20, C=4096, S=4096, replacement="srf")
    sched = Scheduler(cfg)
    long_r = Request(rid=0, input_len=12, output_len=8)
    short_r = Request(rid=1, input_len=4, output_len=8)
    sched.add_request(long_r)
    sched.add_request(short_r)
    sched.get_next_batch()
    long_r.advance(12, 1.0)
    short_r.advance(4, 1.0)
    # decodes grow; at some point M=20 forces a preemption
    for t in range(2, 8):
        b = sched.get_next_batch()
        for r, c in b.items:
            r.advance(c, float(t))
        if sched.num_preemptions:
            break
    assert sched.num_preemptions >= 1
    assert not long_r.running or long_r.m > 0      # long survived
    assert short_r.preemptions >= 1                # short was the victim


def test_nrf_preempts_newest():
    cfg = SchedulerConfig(M=20, C=4096, S=4096, replacement="nrf")
    sched = Scheduler(cfg)
    old_r = Request(rid=0, input_len=4, output_len=10, arrival=0.0)
    new_r = Request(rid=1, input_len=4, output_len=10, arrival=1.0)
    sched.add_request(old_r)
    sched.add_request(new_r)
    sched.get_next_batch()
    old_r.advance(4, 1.0)
    new_r.advance(4, 1.0)
    for t in range(2, 12):
        b = sched.get_next_batch()
        for r, c in b.items:
            r.advance(c, float(t))
        if sched.num_preemptions:
            break
    assert new_r.preemptions >= 1 and old_r.preemptions == 0


def test_max_running_slot_cap():
    cfg = SchedulerConfig(M=10_000, C=4096, S=4096, max_running=3)
    sched = Scheduler(cfg)
    for r in mk_requests([(4, 2, 0.0)] * 8):
        sched.add_request(r)
    batch = sched.get_next_batch()
    assert len(batch) == 3


def test_select_victim_policies():
    rs = mk_requests([(4, 4, 0.0), (4, 4, 1.0), (4, 4, 2.0)])
    rs[0].m, rs[1].m, rs[2].m = 10, 5, 7
    assert select_victim("nrf", rs).rid == 2       # newest arrival
    assert select_victim("srf", rs).rid == 1       # smallest m
    assert select_victim("lrf", rs).rid == 0       # largest m
    assert select_victim("pf", rs) is None
    assert select_victim("nrf", []) is None


@settings(max_examples=60, deadline=None)
@given(
    spec=st.lists(st.tuples(st.integers(1, 20), st.integers(1, 8),
                            st.floats(0, 5)), min_size=1, max_size=20),
    M=st.integers(16, 200),
    name=st.sampled_from(["vllm", "sarathi", "vllm_hy", "sarathi_cs"]),
    repl=st.sampled_from(["nrf", "srf", "lrf"]))
def test_property_all_requests_complete_and_memory_safe(spec, M, name, repl):
    """Any workload + scheduler + policy: terminates, conserves tokens,
    never violates M (provided every request individually fits)."""
    spec = [(I, O, a) for I, O, a in spec if I + O - 1 <= M]
    if not spec:
        return
    sched = make_scheduler(name, M=M, S=256, replacement=repl)
    reqs = mk_requests(spec)
    run_to_completion(sched, reqs)
    assert all(r.finished for r in reqs)
    assert all(r.generated == r.output_len for r in reqs)
