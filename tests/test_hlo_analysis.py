"""HLO collective-byte parser: shapes, replica groups, while multipliers."""
import textwrap

from repro.launch.hlo_analysis import (collective_bytes, shape_bytes,
                                       split_computations)

HLO = textwrap.dedent("""\
    HloModule test

    %cond.1 (p: (s32[], f32[8])) -> pred[] {
      %p = (s32[], f32[8]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %n = s32[] constant(24)
      ROOT %lt = pred[] compare(%i, %n), direction=LT
    }

    %body.1 (p: (s32[], f32[8])) -> (s32[], f32[8]) {
      %p = (s32[], f32[8]) parameter(0)
      %x = f32[8]{0} get-tuple-element(%p), index=1
      %ar = f32[8]{0} all-reduce(%x), channel_id=1, replica_groups=[16,16]<=[256], use_global_device_ids=true, to_apply=%sum
      %i = s32[] get-tuple-element(%p), index=0
      %one = s32[] constant(1)
      %ip = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[8]) tuple(%ip, %ar)
    }

    ENTRY %main (a: f32[8], b: bf16[4,128]) -> f32[8] {
      %a = f32[8]{0} parameter(0)
      %b = bf16[4,128]{1,0} parameter(1)
      %ag = bf16[4,2048]{1,0} all-gather(%b), dimensions={1}, replica_groups=[16,16]<=[256], channel_id=2
      %t0 = (s32[], f32[8]) tuple(%zero, %a)
      %w = (s32[], f32[8]) while(%t0), condition=%cond.1, body=%body.1
      ROOT %out = f32[8]{0} get-tuple-element(%w), index=1
    }
""")


def test_shape_bytes():
    assert shape_bytes("f32[8]") == 32
    assert shape_bytes("bf16[4,128]") == 1024
    assert shape_bytes("(f32[2,2], bf16[4])") == 16 + 8
    assert shape_bytes("pred[]") == 1        # scalar: one element
    assert shape_bytes("s32[10]") == 40


def test_split_computations():
    comps = split_computations(HLO)
    assert "cond.1" in comps and "body.1" in comps and "main" in comps


def test_collectives_with_while_multiplier():
    stats = collective_bytes(HLO, num_devices=256)
    # all-gather appears once at top level: bf16[4,2048] = 16384 B
    assert stats.bytes_by_kind["all-gather"] == 16384
    # all-reduce inside a 24-trip while: f32[8]=32 B * 24
    assert stats.bytes_by_kind["all-reduce"] == 32 * 24
    assert stats.count_by_kind["all-reduce"] == 24
    # link bytes: AG (g-1)/g + AR 2(g-1)/g with g=16
    expect = 16384 * 15 / 16 + 32 * 24 * 2 * 15 / 16
    assert abs(stats.link_bytes - expect) < 1e-6


def test_replica_group_list_form():
    text = ("ENTRY %m (x: f32[4]) -> f32[4] {\n"
            "  ROOT %ar = f32[4]{0} all-reduce(%x), "
            "replica_groups={{0,1},{2,3}}, to_apply=%s\n}\n")
    stats = collective_bytes(text, num_devices=4)
    assert stats.bytes_by_kind["all-reduce"] == 16
    assert abs(stats.link_bytes - 16 * 2 * 1 / 2) < 1e-6
