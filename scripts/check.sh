#!/usr/bin/env sh
# Single offline regression entry point (also: `make check`):
#   1. static analysis — repo-specific checkers (recompile hazards,
#      host syncs, charge audit, config mirroring, and the v2
#      state-safety rules: txn-coverage rollback completeness,
#      stat-mirror engine<->sim parity, async-drain swap protocol);
#      fails on any finding that is neither allow-annotated nor
#      baselined (src/repro/analysis/README.md)
#   2. pytest suite — FAST tier by default (skips tests marked `slow`,
#      the heaviest cross-plane parity sweeps); set CHECK_FULL=1 to run
#      the complete tier-1 suite (what `python -m pytest -x -q` runs)
#      plus the compiled-artifact audit (HLO scan + compile budget)
#   3. every figure benchmark at smoke sizes (includes fig_engine_wall
#      and fig_prefix_sharing); writes experiments/bench/BENCH_smoke.json
#      and the repo-root BENCH_8.json perf headline
#   4. perf gate — the paged plane must match or beat the batched dense
#      plane on wall-clock tok/s (BENCH_8.json ratio >= 1.0)
#   5. trie gate — radix-trie partial-prefix lookup must attach strictly
#      more shared tokens than exact-match lookup on the branching
#      conversation workload (BENCH_9.json ratio > 1.0)
# Set CHECK_CHAOS=1 to additionally run the complete fault-injection
# chaos matrix (tests/test_chaos.py including its `slow` sweeps); the
# fast tier already covers the unmarked chaos smoke tests.
# Extra arguments are forwarded to pytest (e.g. scripts/check.sh -k engine).
set -e
cd "$(dirname "$0")/.."

echo "== static analysis =="
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m repro.analysis src/

if [ -n "${CHECK_FULL:-}" ]; then
    echo "== compiled-artifact audit =="
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m repro.analysis \
        src/repro/analysis --artifact
    echo "== tier-1 tests (full) =="
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
else
    echo "== tier-1 tests (fast tier; CHECK_FULL=1 for the full suite) =="
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q \
        -m "not slow" "$@"
fi

if [ -n "${CHECK_CHAOS:-}" ]; then
    echo "== chaos suite (full fault-injection matrix) =="
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q \
        tests/test_chaos.py
fi

echo "== smoke benchmarks =="
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m benchmarks.run --smoke

echo "== perf gate (BENCH_8.json) =="
python - <<'PY'
import json
import sys

d = json.load(open("BENCH_8.json"))
r = d["paged_vs_batched_tps_ratio"]
print(f"paged/batched tok/s ratio: {r:.2f}  "
      f"(shared/unshared: {d['shared_vs_unshared_tps_ratio']:.2f})")
if r < 1.0:
    print("FAIL: paged plane slower than batched dense plane")
    sys.exit(1)
PY

echo "== trie gate (BENCH_9.json) =="
python - <<'PY'
import json
import sys

d = json.load(open("BENCH_9.json"))
r = d["trie_vs_exact_shared_tokens_ratio"]
print(f"trie/exact shared-tokens ratio: {r:.2f}  "
      f"(tok/s ratio: {d['trie_vs_exact_tps_ratio']:.2f})")
if r <= 1.0:
    print("FAIL: radix trie attaches no more than exact-match lookup")
    sys.exit(1)
PY
