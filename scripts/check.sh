#!/usr/bin/env sh
# Single offline regression entry point (also: `make check`):
#   1. pytest suite — FAST tier by default (skips tests marked `slow`,
#      the heaviest cross-plane parity sweeps); set CHECK_FULL=1 to run
#      the complete tier-1 suite (what `python -m pytest -x -q` runs)
#   2. every figure benchmark at smoke sizes (includes fig_engine_wall
#      and fig_prefix_sharing)
# Extra arguments are forwarded to pytest (e.g. scripts/check.sh -k engine).
set -e
cd "$(dirname "$0")/.."

if [ -n "${CHECK_FULL:-}" ]; then
    echo "== tier-1 tests (full) =="
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"
else
    echo "== tier-1 tests (fast tier; CHECK_FULL=1 for the full suite) =="
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q \
        -m "not slow" "$@"
fi

echo "== smoke benchmarks =="
python -m benchmarks.run --smoke
