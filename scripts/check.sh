#!/usr/bin/env sh
# Single offline regression entry point (also: `make check`):
#   1. tier-1 pytest suite
#   2. every figure benchmark at smoke sizes (includes fig_engine_wall)
# Extra arguments are forwarded to pytest (e.g. scripts/check.sh -k engine).
set -e
cd "$(dirname "$0")/.."

echo "== tier-1 tests =="
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@"

echo "== smoke benchmarks =="
python -m benchmarks.run --smoke
