"""Radix-trie vs exact-match prefix registry (PR 9).

The PR 8 registry was all-or-nothing: a probe attached cached pages
only when the FULL queried chain was device-resident, so branching
conversations — shared system prompt, divergent turns, a unique final
user message per request — scored near zero even though most of every
prompt was sitting in the pool.  The radix trie converts each shared
tree path into a *partial* hit: the longest cached run attaches and
only the divergent tail computes.

This benchmark runs the SAME engine twice per workload — once with
``prefix_lookup="trie"`` (default) and once with the ``"exact"``
ablation — on two workloads:

  * ``conversation_tree`` — the tentpole's exit-criterion shape: every
    prompt ends in a unique page, so exact matching can only attach up
    to the probe cap while the trie attaches every shared tree path
  * ``zipf_shared_prefix`` — the §6 replacement workload, checking the
    trie never regresses the hot-template traffic the break-even
    policy was tuned on

Asserted claims: token-identical outputs per workload across modes
(partial attach must never change a single token), strictly MORE
shared tokens and strictly lower wall time for the trie on
``conversation_tree``.  The headline ratio
``trie_vs_exact_shared_tokens_ratio`` feeds BENCH_9.json and the
scripts/check.sh gate (> 1.0).
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import print_table, save_json


def _run(cfg, params, cm, reqs, *, mode):
    from repro.core import make_scheduler
    from repro.serving import Engine, EngineConfig

    sched = make_scheduler("vllm", 400, S=512, replacement="srf",
                           prefix_lookup=mode)
    eng = Engine(cfg, params, sched,
                 EngineConfig(nslots=8, cache_len=64, chunk=16,
                              plane="paged", page_size=8,
                              prefix_sharing=True, share_jits=True),
                 cost_model=cm)
    eng.warmup()                   # compiles land OUTSIDE the timed window
    t0 = time.perf_counter()
    res = eng.run(reqs)
    wall = time.perf_counter() - t0
    toks = sum(len(v) for v in res.outputs.values())
    return dict(outputs=res.outputs, wall_s=wall, tokens=toks,
                tps=toks / wall,
                peak_pages=max(b.pages_used for b in res.metrics.batches),
                prefix_hits=eng.allocator.stats["prefix_hits"],
                shared_tokens=eng.allocator.stats["prefix_shared_tokens"],
                trie_hits=res.swap_stats["trie_hits"],
                partial_hit_tokens=res.swap_stats["partial_hit_tokens"])


def run(smoke: bool = False) -> dict:
    import jax

    from repro.configs import get_config
    from repro.core import TheoreticalCostModel, get_hardware
    from repro.data.workloads import conversation_tree, zipf_shared_prefix
    from repro.models import model as M

    cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                              dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    cm = TheoreticalCostModel(cfg, get_hardware("tpu_v5e"))

    n = 8 if smoke else 16
    workloads = {
        # 48-token prompts, 6 pages each: 3 system + 2 turn + 1 unique
        "conversation_tree": lambda: conversation_tree(
            n=n, page_size=8, system_pages=3, turn_pages=1, branching=2,
            depth=2, output_len=4, vocab=cfg.vocab_size, seed=0),
        "zipf_shared_prefix": lambda: zipf_shared_prefix(
            n=max(n, 12), num_groups=4, page_size=8, input_len=48,
            output_len=4, vocab=cfg.vocab_size, seed=1),
    }
    rows, payload = [], {}
    for name, make_wl in workloads.items():
        point = {}
        for mode in ("exact", "trie"):
            point[mode] = _run(cfg, params, cm, make_wl(), mode=mode)
        ex, tr = point["exact"], point["trie"]
        assert tr["outputs"] == ex["outputs"], \
            f"{name}: partial-prefix attach changed tokens"
        rows.append([name,
                     ex["shared_tokens"], tr["shared_tokens"],
                     tr["partial_hit_tokens"],
                     f"{ex['tps']:.1f}", f"{tr['tps']:.1f}",
                     ex["peak_pages"], tr["peak_pages"],
                     ex["trie_hits"], tr["trie_hits"]])
        payload[name] = {
            m: {k: v for k, v in point[m].items() if k != "outputs"}
            for m in point}
    print_table(
        f"fig_radix_trie — exact vs radix-trie prefix lookup "
        f"(paged plane, page_size=8, {n} conversation requests)",
        ["workload", "shared (exact)", "shared (trie)", "partial toks",
         "tok/s (exact)", "tok/s (trie)", "pages (exact)",
         "pages (trie)", "hits (exact)", "hits (trie)"], rows)

    conv = payload["conversation_tree"]
    # the exit criterion: on branching conversations the trie attaches
    # strictly more shared tokens AND finishes strictly faster — the
    # extra attached pages skip their prefill rounds outright
    assert conv["trie"]["shared_tokens"] > conv["exact"]["shared_tokens"], conv
    assert conv["trie"]["partial_hit_tokens"] > 0, conv
    assert conv["trie"]["wall_s"] < conv["exact"]["wall_s"], conv
    # the zipf replacement workload must not regress under the trie
    zipf = payload["zipf_shared_prefix"]
    assert zipf["trie"]["shared_tokens"] >= zipf["exact"]["shared_tokens"], zipf
    print("tokens identical across lookup modes: True")
    payload["trie_vs_exact_shared_tokens_ratio"] = (
        conv["trie"]["shared_tokens"]
        / max(conv["exact"]["shared_tokens"], 1))
    payload["trie_vs_exact_tps_ratio"] = (conv["trie"]["tps"]
                                          / conv["exact"]["tps"])
    save_json("fig_radix_trie", payload)
    return payload


if __name__ == "__main__":
    run()
