"""Fig. 9 — multi-batch scheduler comparison under high contention
(W=1024), fixed (I, O) grids (§5.5)."""
from __future__ import annotations

from benchmarks.common import cost_model, print_table, save_json
from repro.core.simulator import fresh_requests, run_sim

SCHEDULERS = ("vllm", "sarathi", "sarathi_cs")


def run(W: int = 1024) -> dict:
    cm = cost_model()
    out = {}
    rows = []
    for O in (1, 32, 1024):
        for I in (1, 32, 1024):
            if I + O - 1 > 4096:
                continue
            for name in SCHEDULERS:
                reqs = fresh_requests([(I, O, 0.0)] * W)
                r = run_sim(name, reqs, cm, M=100_000)
                s = r.summary()
                out[f"{name}_I{I}_O{O}"] = s
                rows.append([name, I, O, f"{s['latency']:.2f}",
                             f"{s['mean_ttft']:.3f}",
                             f"{s['mean_tpot']*1e3:.2f}",
                             int(s["preemptions"]),
                             f"{s['mean_batch_size']:.1f}",
                             f"{s['mean_kv_used']/100_000:.0%}"])
    print_table(f"Fig 9 — W={W}, M=100K (A100): latency/TPOT/preemption",
                ["scheduler", "I", "O", "latency(s)", "TTFT(s)",
                 "TPOT(ms)", "preempt", "batch", "KV use"], rows)

    # paper claims (high contention): vLLM lowest latency except when
    # large O triggers preemptions; Sarathi up to ~13% higher latency but
    # multi-x lower TPOT; preemptions increase with O.  The TPOT/latency
    # separations only materialize in the full W=1024 contention regime
    # (at smoke sizes decode batches stay small and TPOTs converge), so
    # they are asserted only there; preemption monotonicity in O is
    # structural and holds at every W.
    if W >= 1024:
        for I in (1, 32):
            v = out[f"vllm_I{I}_O32"]
            s = out[f"sarathi_I{I}_O32"]
            assert s["latency"] >= v["latency"] * 0.98
            assert s["mean_tpot"] < v["mean_tpot"]
    assert (out["vllm_I1_O1024"]["preemptions"]
            >= out["vllm_I1_O32"]["preemptions"])
    save_json("fig09_schedulers", out)
    return out


if __name__ == "__main__":
    run()
