"""Fig. 12 (+ App. B Fig. 18) — is increasing M a silver bullet? (§5.7)

O=32, W=1024, M from 100 to 1M: preemption helps ~2x under tight memory,
hurts once memory is ample; even M=1M leaves the cache underutilized.
"""
from __future__ import annotations

from benchmarks.common import cost_model, print_table, save_json
from repro.core.simulator import fresh_requests, run_sim


def run(W: int = 1024) -> dict:
    cm = cost_model()
    out = {}
    rows = []
    for I in (1, 8):
        for M in (100, 1_000, 10_000, 100_000, 1_000_000):
            for name in ("vllm", "vllm_pf", "sarathi", "sarathi_pf"):
                reqs = fresh_requests([(I, 32, 0.0)] * W)
                s = run_sim(name, reqs, cm, M=M).summary()
                out[f"{name}_I{I}_M{M}"] = s
            v, vp = out[f"vllm_I{I}_M{M}"], out[f"vllm_pf_I{I}_M{M}"]
            sa, sp = out[f"sarathi_I{I}_M{M}"], out[f"sarathi_pf_I{I}_M{M}"]
            rows.append([I, M, f"{v['latency']:.2f}", f"{vp['latency']:.2f}",
                         f"{vp['latency']/v['latency']:.2f}x",
                         f"{sa['latency']:.2f}", f"{sp['latency']:.2f}",
                         f"{sp['latency']/sa['latency']:.2f}x",
                         int(v["preemptions"]),
                         f"{sa['mean_kv_used']/M:.0%}"])
    print_table("Fig 12 — O=32 W=1024, varying M (ratio >1: preemption "
                "helps; <1: hurts)",
                ["I", "M", "vllm", "vllm_pf", "PF/vllm", "sarathi",
                 "sarathi_pf", "PF/sarathi", "vllm preempt",
                 "sarathi KV use"], rows)
    # paper: ~2x win at M=100; no win at M>=10K; low utilization at 1M
    for I in (1, 8):
        small = (out[f"sarathi_pf_I{I}_M100"]["latency"]
                 / out[f"sarathi_I{I}_M100"]["latency"])
        large = (out[f"vllm_pf_I{I}_M10000"]["latency"]
                 / out[f"vllm_I{I}_M10000"]["latency"])
        assert small > 1.4, small
        assert large <= 1.0 + 1e-9, large
        assert (out[f"sarathi_I{I}_M1000000"]["mean_kv_used"]
                / 1_000_000 < 0.2)
    save_json("fig12_vary_m", out)
    return out


if __name__ == "__main__":
    run()
