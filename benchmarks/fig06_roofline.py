"""Figs. 5-6 — operator breakdown + roofline placement (§5.2).

For prefill/decode batches over (c, m, B): operator time shares, each
attention point's intensity (FLOPs/RW) against the hardware turning
point, and the §5.2 remark checks (attention memory-bound even for
prefill; whole decode batches can be compute-bound).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import cost_model, print_table, save_json
from repro.configs import get_config
from repro.core.cost_model import BatchSpec, attention_flops_rw, get_hardware
from repro.core.slo import balanced_intensity

CFG = get_config("llama2-7b")


def run() -> dict:
    out = {"points": []}
    for hw_name in ("h100", "tpu_v5e"):
        hw = get_hardware(hw_name)
        turning = hw.flops / hw.hbm_bw
        cm = cost_model("llama2-7b", hw_name)
        rows = []
        for phase, c, m, B in [
            ("prefill", 128, 0, 8), ("prefill", 1024, 0, 8),
            ("prefill", 4096, 0, 8),
            ("decode", 1, 512, 32), ("decode", 1, 4096, 32),
            ("decode", 1, 4096, 256),
        ]:
            fl, rw = attention_flops_rw(c, m, CFG, 1, 2)
            fl, rw = fl * B, rw * B
            intensity = fl / rw
            spec = (BatchSpec(prefills=[(c, m)] * B) if phase == "prefill"
                    else BatchSpec(decodes=[(c, m)] * B))
            times = cm.op_times(spec)
            total = sum(times.values())
            attn_t = times["attn_prefill"] + times["attn_decode"]
            matmul_t = times["qkv_proj"] + times["o_proj"] + times["mlp"]
            terms = cm.batch_terms(spec)
            batch_bound = ("compute" if terms["compute_s"] > terms["memory_s"]
                           else "memory")
            rows.append([phase, c, m, B, f"{intensity:.1f}",
                         f"{turning:.0f}",
                         "mem" if intensity < turning else "comp",
                         f"{attn_t/total:.0%}", f"{matmul_t/total:.0%}",
                         batch_bound])
            out["points"].append(dict(hw=hw_name, phase=phase, c=c, m=m,
                                      B=B, intensity=intensity,
                                      turning=turning,
                                      batch_bound=batch_bound))
        print_table(
            f"Fig 5/6 — roofline placement on {hw_name} "
            f"(turning point {turning:.0f} FLOPs/B)",
            ["phase", "c", "m", "B", "attn FLOPs/B", "turning",
             "attn bound", "attn t%", "matmul t%", "batch bound"], rows)

    # §5.2 remark checks
    h100 = get_hardware("h100")
    for c in (128, 1024, 4096):
        fl, rw = attention_flops_rw(c, 0, CFG, 1, 2)
        assert fl / rw < h100.flops / h100.hbm_bw  # attention memory-bound
    # intensity convergence: prefill -> H, decode -> 2 (Llama-2 MHA)
    out["intensity_prefill_limit"] = balanced_intensity(128, 32, 32, 4096)
    out["intensity_decode_limit"] = balanced_intensity(128, 32, 32, 1)
    print(f"\nintensity limits: prefill={out['intensity_prefill_limit']:.0f}"
          f" (paper: 128), decode={out['intensity_decode_limit']:.2f}"
          f" (paper: ~2)")
    save_json("fig06_roofline", out)
    return out


if __name__ == "__main__":
    run()
