"""fig_fault_recovery — graceful degradation under injected faults
(PR 7; DBMS-style step transactions + deterministic fault plans).

The serving engine wraps every scheduler batch in a step transaction
and recovers from injected control-plane faults through a three-rung
degradation ladder:

  1. retry-in-place   — transient store failures retried with
                        exponential backoff charged to virtual time,
  2. rollback + retry — a mid-step fault rolls allocator / store /
                        scheduler / requests back to batch start,
  3. degrade to       — corrupt host snapshots are dropped and the
     recompute          victim re-prefills from its prompt.

This benchmark sweeps fault intensity (a scale on a mixed
``FaultSpec``: transient + permanent store failures + snapshot
corruption) over a swap-mode workload with real preemption churn and
reports, per point, wall tok/s plus the ladder's counters.  The
asserted contract is the paper-level one: **fault recovery never
changes tokens** — every point's outputs are identical to the
fault-free run — and nothing leaks (the swap store drains to empty).
"""
from __future__ import annotations

import time

from benchmarks.common import print_table, save_json


def _build(faults):
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.core import TheoreticalCostModel, get_hardware, \
        make_scheduler
    from repro.models import model as M
    from repro.serving import Engine, EngineConfig

    cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                              dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    cm = TheoreticalCostModel(cfg, get_hardware("tpu_v5e"))
    sched = make_scheduler("vllm", 60, S=128, replacement="srf",
                           preempt_mode="swap")
    eng = Engine(cfg, params, sched,
                 EngineConfig(nslots=4, cache_len=64, chunk=16,
                              faults=faults),
                 cost_model=cm)
    return cfg, eng


def _requests(cfg, n):
    import numpy as np

    from repro.core import Request

    rs = np.random.RandomState(0)
    out = []
    for i in range(n):
        I, O = int(rs.randint(8, 25)), int(rs.randint(3, 9))
        prompt = rs.randint(0, cfg.vocab_size, size=I).tolist()
        out.append(Request(rid=i, input_len=I, output_len=O,
                           arrival=0.0, prompt=prompt))
    return out


def run(smoke: bool = False) -> dict:
    from repro.serving.faults import FaultSpec

    scales = [0.0, 1.0] if smoke else [0.0, 0.25, 0.5, 1.0]
    n = 5 if smoke else 10
    rows, payload = [], {}
    baseline = None
    for x in scales:
        spec = FaultSpec(seed=7, p_store_transient=0.4 * x,
                         p_store_permanent=0.2 * x, p_corrupt=0.3 * x)
        cfg, eng = _build(spec if x else None)
        reqs = _requests(cfg, n)
        t0 = time.perf_counter()
        res = eng.run(reqs)
        wall = time.perf_counter() - t0
        assert len(eng.swap_store) == 0, "store leaked entries"
        if baseline is None:
            baseline = res.outputs
            assert res.metrics.num_swaps > 0, \
                "baseline must exercise swap preemption"
        assert res.outputs == baseline, \
            f"fault recovery changed tokens at scale={x}"
        toks = sum(len(v) for v in res.outputs.values())
        rec, sw = eng.recovery_stats, eng.swap_stats
        point = dict(scale=x, tps=toks / wall,
                     retries=sw["transient_retries"],
                     backoff_s=sw["backoff_s"],
                     rollbacks=rec["rollbacks"],
                     permanent=sw["permanent_store_failures"],
                     integrity=rec["integrity_failures"],
                     degraded=rec["degraded_recomputes"],
                     makespan=res.metrics.makespan)
        rows.append([f"{x:.2f}", f"{point['tps']:.1f}",
                     point["retries"], f"{point['backoff_s']:.2f}",
                     point["rollbacks"], point["permanent"],
                     point["degraded"]])
        payload[f"scale_{x}"] = point
    print_table(
        "fig_fault_recovery — degradation ladder vs fault intensity "
        f"(swap-mode slot plane, {n} requests; tokens identical at "
        "every point)",
        ["fault scale", "tok/s", "retries", "backoff s", "rollbacks",
         "permanent", "degraded"], rows)

    clean = payload["scale_0.0"]
    assert clean["rollbacks"] == clean["retries"] == 0, clean
    worst = payload[f"scale_{scales[-1]}"]
    assert worst["retries"] + worst["permanent"] + worst["rollbacks"] \
        + worst["integrity"] > 0, \
        "max fault scale must exercise the recovery ladder"
    print("tokens identical across all fault scales: True")
    save_json("fig_fault_recovery", payload)
    return payload


if __name__ == "__main__":
    import sys

    sys.path.insert(0, "src")
    run()
