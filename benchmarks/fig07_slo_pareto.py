"""Fig. 7 — (c, m) pareto curves that pin the hybrid-batch time at the
TPOT threshold (§5.3, top-down SLO attainment)."""
from __future__ import annotations

from benchmarks.common import cost_model, print_table, save_json
from repro.core.slo import pareto_curve


def run() -> dict:
    out = {}
    rows = []
    for hw in ("a100", "h100"):
        cm = cost_model("llama2-7b", hw)
        for n_pre in (8, 32, 128):
            for n_dec in (8, 32, 128):
                pts = pareto_curve(cm, num_prefill=n_pre, num_decode=n_dec,
                                   threshold=1.0,
                                   cs=(1, 16, 64, 256, 1024, 4096))
                key = f"{hw}_p{n_pre}_d{n_dec}"
                out[key] = [(p.c, p.m) for p in pts]
                for p in pts:
                    rows.append([hw, n_pre, n_dec, p.c, p.m,
                                 f"{p.batch_time:.3f}"])
    print_table("Fig 7 — (c, m) with hybrid batch time == 1 s",
                ["hw", "#prefill", "#decode", "c", "m", "time(s)"],
                rows[:24])
    print(f"... ({len(rows)} rows total; H100 admits larger c/m intercepts)")
    # H100 dominates A100 at equal config (larger feasible m)
    for n_pre, n_dec in ((8, 8), (32, 32)):
        a = dict(out[f"a100_p{n_pre}_d{n_dec}"])
        h = dict(out[f"h100_p{n_pre}_d{n_dec}"])
        for c in a:
            if c in h:
                assert h[c] >= a[c]
    save_json("fig07_slo_pareto", out)
    return out


if __name__ == "__main__":
    run()
