"""Appendix C — ranking-based insertion priorities on heterogeneous
workloads: Rank_I vs Rank_O (hypothetical) vs arrival order."""
from __future__ import annotations

from benchmarks.common import cost_model, print_table, save_json
from repro.core.simulator import run_sim
from repro.data import hetero_mix

MIXES = (("LILO", "SILO"), ("LILO", "LISO"), ("SISO", "SILO"),
         ("LISO", "SILO"))


def run(W: int = 256) -> dict:
    cm = cost_model()
    out = {}
    rows = []
    for mix in MIXES:
        for ranking, label in (("arrival", "Rank_org"), ("input", "Rank_I"),
                               ("output", "Rank_O")):
            reqs = hetero_mix(mix, W, seed=7)
            s = run_sim("vllm", reqs, cm, M=20_000, ranking=ranking).summary()
            out[f"{'+'.join(mix)}_{label}"] = s
            rows.append(["+".join(mix), label, f"{s['latency']:.2f}",
                         f"{s['mean_ttft']:.3f}",
                         f"{s['mean_tpot']*1e3:.2f}",
                         int(s["preemptions"])])
    print_table(f"App. C — heterogeneous ranking (W={W}, M=20K)",
                ["mix", "ranking", "latency(s)", "TTFT(s)", "TPOT(ms)",
                 "preempt"], rows)
    # paper: Rank_I generally wins latency+TTFT on eviction-heavy mixes
    for mix in ("LILO+SILO", "LILO+LISO"):
        assert (out[f"{mix}_Rank_I"]["mean_ttft"]
                <= out[f"{mix}_Rank_org"]["mean_ttft"] * 1.05)
        assert (out[f"{mix}_Rank_I"]["latency"]
                <= out[f"{mix}_Rank_org"]["latency"] * 1.05)
    save_json("appc_ranking", out)
    return out


if __name__ == "__main__":
    run()
