"""Fig. 14 (+ App. D) — SRF vs NRF on realistic workloads (§8).

Relative latencies of NRF / SRF / SRF+Hist on AzureConv-like and
LongForm-like traces, with the paper's output-length x2 and M x1/2
contention scalings, plus the two upper bounds (infinite M; hardware-
bound 'Theoretical' with full bandwidth overlap).
"""
from __future__ import annotations

from benchmarks.common import cost_model, print_table, save_json
from repro.core.simulator import run_sim
from repro.data import azureconv_like, longform_like

BASE_M = 100_000


def trace(kind: str, o_scale: float, n: int, seed: int = 0):
    if kind == "azureconv":
        # 1-hour trace compressed to keep sim time sane at n<<19.7K
        return azureconv_like(n, duration_s=600.0, o_scale=o_scale,
                              seed=seed)
    return longform_like(n, duration_s=100.0, o_scale=o_scale, seed=seed)


def run(n: int = 384) -> dict:
    cm = cost_model("llama2-7b", "a100")
    bound = cost_model("llama2-7b", "a100", flops_eff=1.0, bw_eff=1.0,
                       attn_bw_eff=1.0)
    out = {}
    rows = []
    for kind in ("azureconv", "longform"):
        for o_scale, m_scale in ((1.0, 1.0), (2.0, 1.0), (1.0, 0.5),
                                 (2.0, 0.5)):
            M = int(BASE_M * m_scale)
            S = 128 * 1024
            nrf = run_sim("vllm", trace(kind, o_scale, n), cm, M=M, S=S,
                          replacement="nrf").latency
            srf = run_sim("vllm", trace(kind, o_scale, n), cm, M=M, S=S,
                          replacement="srf").latency
            hist = run_sim("vllm", trace(kind, o_scale, n), cm, M=M, S=S,
                           replacement="srf", use_histogram=True).latency
            inf = run_sim("vllm", trace(kind, o_scale, n), cm,
                          M=1 << 40, S=S).latency
            theo = run_sim("vllm", trace(kind, o_scale, n), bound,
                           M=1 << 40, S=S).latency
            key = f"{kind}_o{o_scale}_m{m_scale}"
            out[key] = dict(nrf=nrf, srf=srf, srf_hist=hist,
                            infinite_m=inf, theoretical=theo)
            rows.append([kind, o_scale, m_scale, "1.00",
                         f"{srf/nrf:.3f}", f"{hist/nrf:.3f}",
                         f"{inf/nrf:.3f}", f"{theo/nrf:.3f}"])
    print_table(f"Fig 14 — relative latency vs NRF (n={n} requests)",
                ["workload", "O scale", "M scale", "NRF", "SRF",
                 "SRF+Hist", "Infinite M", "Theoretical"], rows)
    # paper: SRF/SRF+Hist never regress; upper bounds are lower
    for key, d in out.items():
        assert d["srf"] <= d["nrf"] * 1.01, key
        assert min(d["srf"], d["srf_hist"]) <= d["nrf"] * 1.005, key
        assert d["infinite_m"] <= d["nrf"] * 1.001, key
        assert d["theoretical"] <= d["infinite_m"], key
    save_json("fig14_srf", out)
    return out


if __name__ == "__main__":
    run()
