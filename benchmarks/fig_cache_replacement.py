"""fig_cache_replacement — break-even cache replacement + host demotion
on the page pool (paper §6 five-minute rule / §8; PR 5).

The repo's §6 machinery used to only COMPUTE break-even intervals;
nothing consumed them.  This benchmark exercises the policy loop end to
end: a Zipf-skewed hot-prefix workload (``data.workloads.
zipf_shared_prefix`` — a few hot prompt templates re-referenced
constantly, a long tail of COLD templates with LONGER prefixes, the
analytics shape of arXiv 2403.05821) runs through the paged engine under
a page pool deliberately too small to cache every template, comparing:

  * ``lru``        — recency-only registry eviction (the old hard-wired
    behaviour): the cold long-prefix scan traffic flushes hot entries.
  * ``break_even`` — §6 Eq. 5 replacement: entries are scored by
    observed idle time over their break-even residency interval; long
    prefixes have SHORTER intervals (weight-load amortizes) so the cold
    tail is evicted first and hot templates stay resident.
  * ``break_even`` + host demotion — evicted prefix pages are demoted
    into the KVSwapStore instead of discarded; a later registry hit on a
    host-resident prefix PROMOTES it back through the swap path (charged
    ``swap_time``), so a capacity eviction costs a swap-in, not a
    recompute — the full Fig. 8 spectrum.

Reported per policy: prefix hits and shared (compute-skipped) tokens —
the hit-rate signal — reclaim + skipped-reclaim counts, demotions /
promotions, and wall tok/s.

Asserted: outputs are TOKEN-IDENTICAL across all three configurations
(replacement is a memory/compute optimization, never a semantic one),
and ``break_even``+demotion achieves strictly more shared prefix tokens
(higher hit rate) than ``lru`` on the skewed workload.
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import print_table, save_json

M_TOKENS = 256          # pool: 32 pages of 8 — too small for all templates
PAGE = 8


def _run(cfg, params, cm, reqs, *, policy, demotion):
    from repro.core import make_scheduler
    from repro.serving import Engine, EngineConfig

    sched = make_scheduler("vllm", M_TOKENS, S=512, replacement="srf")
    eng = Engine(cfg, params, sched,
                 EngineConfig(nslots=4, cache_len=64, chunk=16,
                              plane="paged", page_size=PAGE,
                              cache_policy=policy,
                              cache_demotion=demotion),
                 cost_model=cm)
    t0 = time.perf_counter()
    res = eng.run(reqs)
    wall = time.perf_counter() - t0
    toks = sum(len(v) for v in res.outputs.values())
    st = eng.allocator.stats
    return dict(outputs=res.outputs, wall_s=wall, tokens=toks,
                tps=toks / wall,
                prefix_hits=st["prefix_hits"],
                shared_tokens=st["prefix_shared_tokens"],
                reclaimed=st["reclaimed"],
                reclaim_skipped=st["reclaim_skipped"],
                demotions=eng.swap_stats["demotions"],
                promotions=eng.swap_stats["promotions"],
                demote_drops=eng.swap_stats["demote_drops"])


def run(smoke: bool = False) -> dict:
    import jax

    from repro.configs import get_config
    from repro.core import TheoreticalCostModel, get_hardware
    from repro.data.workloads import zipf_shared_prefix
    from repro.models import model as M

    cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                              dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    cm = TheoreticalCostModel(cfg, get_hardware("tpu_v5e"))

    n = 24 if smoke else 48
    wl_kw = dict(n=n, num_groups=6, alpha=1.2, page_size=PAGE,
                 prefix_pages=(2, 4), input_len=48, output_len=4,
                 vocab=cfg.vocab_size, seed=3)
    configs = [("lru", "lru", False),
               ("break_even", "break_even", False),
               ("break_even+demote", "break_even", True)]
    rows, payload, outputs = [], {}, {}
    for label, policy, demotion in configs:
        r = _run(cfg, params, cm, zipf_shared_prefix(**wl_kw),
                 policy=policy, demotion=demotion)
        outputs[label] = r.pop("outputs")
        payload[label] = r
        rows.append([label, r["prefix_hits"], r["shared_tokens"],
                     r["reclaimed"], r["reclaim_skipped"],
                     r["demotions"], r["promotions"],
                     f"{r['tps']:.1f}"])
    print_table(
        f"fig_cache_replacement — Zipf hot-prefix workload "
        f"({n} requests, 6 templates, pool={M_TOKENS} tokens, page={PAGE})",
        ["policy", "hits", "shared toks", "reclaims", "skipped",
         "demoted", "promoted", "tok/s"], rows)

    # token-identical across every replacement configuration
    assert outputs["lru"] == outputs["break_even"] \
        == outputs["break_even+demote"], \
        "cache replacement changed generated tokens"
    # the point of §6/§8: cost-driven replacement + demotion tier beats
    # hit-rate-blind LRU on the skewed workload — strictly
    lru, bed = payload["lru"], payload["break_even+demote"]
    assert bed["shared_tokens"] > lru["shared_tokens"], (lru, bed)
    assert bed["promotions"] > 0, bed
    print("tokens identical across lru / break_even / "
          "break_even+demote: True")
    save_json("fig_cache_replacement", payload)
    return payload


if __name__ == "__main__":
    run()
