"""Fig. 11 — preemption-free (*pf) vs non-PF at O=W=1024 (§5.6):
PF lowers latency (no refills) and TPOT but explodes TTFT; effective
batch size ~= M/(I+O)."""
from __future__ import annotations

from benchmarks.common import cost_model, print_table, save_json
from repro.core.simulator import fresh_requests, run_sim


def run(W: int = 1024) -> dict:
    cm = cost_model()
    M = 100_000
    out = {}
    rows = []
    for I in (1, 128, 1024):
        O = 1024
        for name in ("vllm", "vllm_pf", "sarathi", "sarathi_pf"):
            reqs = fresh_requests([(I, O, 0.0)] * W)
            s = run_sim(name, reqs, cm, M=M).summary()
            out[f"{name}_I{I}"] = s
            rows.append([name, I, f"{s['latency']:.1f}",
                         f"{s['mean_ttft']:.2f}", f"{s['max_ttft']:.1f}",
                         f"{s['mean_tpot']*1e3:.1f}",
                         int(s["preemptions"]),
                         f"{s['mean_batch_size']:.1f}",
                         f"{M/(I+O):.0f}"])
    print_table("Fig 11 — O=W=1024: PF vs non-PF",
                ["scheduler", "I", "latency(s)", "TTFT(s)", "maxTTFT",
                 "TPOT(ms)", "preempt", "batch", "M/(I+O)"], rows)
    for I in (1, 128, 1024):
        pf, npf = out[f"vllm_pf_I{I}"], out[f"vllm_I{I}"]
        if npf["preemptions"] > 0:
            assert pf["latency"] <= npf["latency"] * 1.02   # no refills
        assert pf["mean_tpot"] <= npf["mean_tpot"] * 1.05   # TPOT drops
        # effective batch size ~ M/(I+O) (§5.6 remark)
        expect = 100_000 / (I + 1024)
        assert abs(pf["mean_batch_size"] - expect) / expect < 0.4
    # TTFT blow-up (paper: up to 1000x) holds while admission is cheap;
    # at I ~ 1024 memory binds either way and TTFTs converge
    for I in (1, 128):
        assert (out[f"vllm_pf_I{I}"]["mean_ttft"]
                >= out[f"vllm_I{I}"]["mean_ttft"])
    r = out["vllm_pf_I1"]["max_ttft"] / max(out["vllm_I1"]["max_ttft"], 1e-9)
    assert r > 100  # the multi-100x TTFT penalty at small I
    save_json("fig11_preemption_free", out)
    return out


if __name__ == "__main__":
    run()
