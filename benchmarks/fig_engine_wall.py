"""fig_engine_wall — measured wall-time throughput of the execution
planes (beyond-paper §Perf).

The paper's cost model prices FLOPs and HBM bytes, but the PR-1 engine
burned wall-clock on overheads the model never sees: a fresh XLA
compile for every distinct prefill tail length, and a device->host copy
of the full (nslots, vocab) logits array per sampled token.  This
benchmark runs the SAME workload through

  * ``legacy``   — the PR-1 plane: per-request exact-shape chunk loop
                   (one compile per distinct tail length),
  * ``batched``  — the shape-stable plane: bucketed ``prefill_many``
                   over the whole slot grid with fused on-device
                   sampling and async swap-out transfers,
  * ``batched+deferred`` — ditto, with the once-per-step deferred
                   cache append on the decode path,
  * ``paged``    — pooled per-layer KV pages + block tables (PR 4):
                   the allocator's page map IS the memory layout;
                   decode flash-decodes over scalar-prefetched pages,

and reports wall-time throughput (tok/s), the number of distinct XLA
compiles, and the speedup over legacy.  The shape-stable planes run
with ``share_jits=True`` + ``Engine.warmup()`` (PR 8) so the timed
window measures steady-state serving, not first-call compiles; the
legacy plane cannot warm up (its shapes are data-dependent — that
pathology is the baseline).  Outputs must be token-identical across
planes (the correctness contract), the batched/paged planes' compile
counts must stay a small constant, and the paged plane's fused prefill
kernel + coalesced uploads must win wall-clock over the batched dense
plane.  (Shared-prefix reuse has its own figure:
``fig_prefix_sharing``.)
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import print_table, save_json


def _workload(cfg, n, seed=0):
    import numpy as np

    from repro.core import Request

    rs = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        # prompt lengths drawn wide so the legacy plane sees many
        # distinct tail lengths (each one a fresh compile)
        I, O = int(rs.randint(5, 40)), int(rs.randint(3, 9))
        reqs.append(Request(rid=i, input_len=I, output_len=O, arrival=0.0,
                            prompt=rs.randint(0, cfg.vocab_size,
                                              size=I).tolist()))
    return reqs


def _run_plane(cfg, params, cm, n_requests, M_kv, *, plane,
               decode_append="inline", async_swap=True, preempt_mode="swap",
               page_size=1, warm=False):
    from repro.core import make_scheduler
    from repro.serving import Engine, EngineConfig

    sched = make_scheduler("vllm", M_kv, S=128, replacement="srf",
                           preempt_mode=preempt_mode)
    eng = Engine(cfg, params, sched,
                 EngineConfig(nslots=4, cache_len=64, chunk=16,
                              plane=plane, decode_append=decode_append,
                              async_swap=async_swap, page_size=page_size,
                              share_jits=warm),
                 cost_model=cm)
    reqs = _workload(cfg, n_requests)
    if warm:
        eng.warmup()               # compiles land OUTSIDE the timed window
    t0 = time.perf_counter()
    res = eng.run(reqs)
    wall = time.perf_counter() - t0
    toks = sum(len(v) for v in res.outputs.values())
    return dict(outputs=res.outputs, wall_s=wall, tokens=toks,
                tps=toks / wall, compiles=res.num_compiles,
                preemptions=res.metrics.num_preemptions,
                swaps=res.metrics.num_swaps,
                batch_wall_s=sum(b.wall_s for b in res.metrics.batches))


def run(smoke: bool = False, n_requests: int = 0) -> dict:
    import jax

    from benchmarks.common import cost_model
    from repro.configs import get_config
    from repro.core import TheoreticalCostModel, get_hardware
    from repro.models import model as M

    n = n_requests or (6 if smoke else 24)
    cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                              dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    cm = TheoreticalCostModel(cfg, get_hardware("tpu_v5e"))
    M_kv = 60                      # tight cache: preemptions + swaps real

    planes = [
        ("legacy", dict(plane="legacy", async_swap=False)),
        ("batched", dict(plane="batched", warm=True)),
        ("batched+deferred", dict(plane="batched",
                                  decode_append="deferred", warm=True)),
        ("paged", dict(plane="paged", page_size=8, warm=True)),
    ]
    results = {}
    for name, kw in planes:
        results[name] = _run_plane(cfg, params, cm, n, M_kv, **kw)

    base = results["legacy"]
    rows = []
    for name, _ in planes:
        r = results[name]
        rows.append([name, r["tokens"], f"{r['wall_s']:.2f}",
                     f"{r['tps']:.1f}", r["compiles"],
                     f"{base['wall_s'] / r['wall_s']:.2f}x",
                     r["preemptions"], r["swaps"]])
    print_table(
        f"fig_engine_wall — execution-plane wall time (reduced tinyllama, "
        f"{n} requests, M={M_kv})",
        ["plane", "tokens", "wall (s)", "tok/s", "XLA compiles",
         "speedup", "preempt", "swaps"], rows)

    # correctness contract: padding/batching/fusion/paging change NO tokens
    for name, _ in planes[1:]:
        assert results[name]["outputs"] == base["outputs"], \
            f"{name} changed generated tokens"
    # shape-stability: the batched AND paged planes compile a small
    # constant number of signatures; legacy compiles per distinct tail
    assert results["batched"]["compiles"] <= 10, results["batched"]["compiles"]
    assert results["paged"]["compiles"] <= 10, results["paged"]["compiles"]
    assert base["compiles"] > results["batched"]["compiles"], \
        (base["compiles"], results["batched"]["compiles"])
    # the point of the exercise: measured wall-time throughput improves
    assert results["batched"]["wall_s"] < base["wall_s"], \
        (results["batched"]["wall_s"], base["wall_s"])
    # PR 8 acceptance: with compiles amortised, the paged plane's fused
    # prefill kernel + coalesced uploads win wall-clock over the
    # batched dense plane
    assert results["paged"]["tps"] >= results["batched"]["tps"], \
        (results["paged"]["tps"], results["batched"]["tps"])
    print("tokens identical across planes: True")

    payload = {name: {k: v for k, v in r.items() if k != "outputs"}
               for name, r in results.items()}
    payload["speedup_batched_vs_legacy"] = base["wall_s"] / \
        results["batched"]["wall_s"]
    payload["paged_vs_batched_tps_ratio"] = (results["paged"]["tps"] /
                                             results["batched"]["tps"])
    save_json("fig_engine_wall", payload)
    return payload


if __name__ == "__main__":
    run()
