"""Fig. 4 — linearity of per-operator times in the Table-3 variables.

Single-variable linear regressions of operator times over their
representative variable (non-attention: c; decode-attention: m;
prefill-attention: c(c+m)); the paper reports R^2 > 0.96 on
A100 and H100 measurements.  Labels here come from the de-rated
theoretical model + measurement noise (profile_synthetic) — the exact
pipeline a GPU deployment runs with real timings.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import cost_model, print_table, save_json
from repro.configs import get_config
from repro.core.cost_model import (BatchSpec, get_hardware,
                                   group_labels_from_theory)


def r2(x: np.ndarray, y: np.ndarray) -> float:
    A = np.stack([x, np.ones_like(x)], 1)
    w, *_ = np.linalg.lstsq(A, y, rcond=None)
    resid = y - A @ w
    return 1.0 - resid.var() / y.var()


def run() -> dict:
    rng = np.random.default_rng(0)
    rows = []
    out = {}
    for hw in ("a100", "h100", "tpu_v5e"):
        cm = cost_model("llama2-7b", hw)
        # non-attention vs c
        cs = np.unique(rng.integers(1, 4096, 80))
        y = np.array([group_labels_from_theory(
            cm, BatchSpec(prefills=[(int(c), 0)]))["nonattn"]
            * rng.lognormal(0, 0.03) for c in cs])
        r2_non = r2(cs.astype(float), y)
        # decode attention vs m (B=16)
        ms = np.unique(rng.integers(1, 8192, 80))
        y = np.array([group_labels_from_theory(
            cm, BatchSpec(decodes=[(1, int(m))] * 16))["attn_decode"]
            * rng.lognormal(0, 0.03) for m in ms])
        r2_dec = r2(ms.astype(float), y)
        # prefill attention vs c(c+m)
        cs = np.unique(rng.integers(16, 4096, 80))
        x = cs.astype(float) ** 2
        y = np.array([group_labels_from_theory(
            cm, BatchSpec(prefills=[(int(c), 0)]))["attn_prefill"]
            * rng.lognormal(0, 0.03) for c in cs])
        r2_pre = r2(x, y)
        rows.append([hw, r2_non, r2_dec, r2_pre])
        out[hw] = dict(nonattn=r2_non, attn_decode=r2_dec,
                       attn_prefill=r2_pre)
    print_table("Fig 4 — R^2 of single-variable linear fits (paper: >0.96)",
                ["hw", "nonattn~c", "decode_attn~m", "prefill_attn~c^2"],
                rows)
    assert all(v > 0.96 for d in out.values() for v in d.values())
    save_json("fig04_cost_linearity", out)
    return out


if __name__ == "__main__":
    run()
