"""Appendix A — low contention (W=32): no evictions; latency is driven by
batch size / prefill speed; Sarathi_nohy degrades with large I."""
from __future__ import annotations

from benchmarks.common import cost_model, print_table, save_json
from repro.core.simulator import fresh_requests, run_sim


def run() -> dict:
    cm = cost_model()
    W, M = 32, 100_000
    out = {}
    rows = []
    for O in (32, 1024):
        for I in (1, 32, 1024):
            for name in ("vllm", "sarathi", "sarathi_nohy"):
                reqs = fresh_requests([(I, O, 0.0)] * W)
                s = run_sim(name, reqs, cm, M=M).summary()
                out[f"{name}_I{I}_O{O}"] = s
                rows.append([name, I, O, f"{s['latency']:.2f}",
                             f"{s['mean_tpot']*1e3:.2f}",
                             int(s["preemptions"]),
                             f"{s['mean_batch_size']:.1f}"])
    print_table("App. A — W=32 (no contention)",
                ["scheduler", "I", "O", "latency(s)", "TPOT(ms)",
                 "preempt", "batch size"], rows)
    assert all(s["preemptions"] == 0 for s in out.values())
    # vLLM fastest or tied; sarathi_nohy hurts for large I (batch collapse)
    for O in (32, 1024):
        assert (out[f"vllm_I32_O{O}"]["latency"]
                <= out[f"sarathi_I32_O{O}"]["latency"] * 1.02)
    assert (out["sarathi_nohy_I1024_O32"]["latency"]
            > out["vllm_I1024_O32"]["latency"])
    save_json("appa_low_contention", out)
    return out


if __name__ == "__main__":
    run()
