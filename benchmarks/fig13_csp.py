"""Fig. 13 — CSP-optimal schedules: preemption is optimal for short
requests, harmful for long ones (§7.1)."""
from __future__ import annotations

from benchmarks.common import cost_model, print_table, save_json
from repro.core.csp import solve_optimal_schedule
from repro.core.simulator import fresh_requests, run_sim


def run() -> dict:
    cm = cost_model()
    O = W = 4
    out = {}
    rows = []
    for I in (1, 4, 16, 32, 64, 256, 1024):
        M = max(2 * I, I + O - 1)
        res = solve_optimal_schedule([(I, O)] * W, M=M, C=4096,
                                     cost_model=cm)
        vllm = run_sim("vllm", fresh_requests([(I, O, 0.0)] * W), cm,
                       M=M).latency
        pf = run_sim("vllm_pf", fresh_requests([(I, O, 0.0)] * W), cm,
                     M=M).latency
        gain_vs_pf = (pf - res.optimal_time) / pf
        out[f"I{I}"] = dict(optimal=res.optimal_time,
                            preemptions=res.num_preemptions,
                            batches=res.num_batches, vllm=vllm, pf=pf,
                            states=res.states_expanded)
        rows.append([I, M, f"{res.optimal_time*1e3:.2f}",
                     res.num_preemptions, res.num_batches,
                     f"{vllm*1e3:.2f}", f"{pf*1e3:.2f}",
                     f"{gain_vs_pf:+.0%}"])
    print_table("Fig 13 — O=W=4, M=max(2I, I+O-1): optimal schedules",
                ["I", "M", "CSP opt (ms)", "preempt", "batches",
                 "vllm (ms)", "vllm_pf (ms)", "opt vs PF"], rows)
    # paper: CSP preempts for small I, avoids preemption for large I
    assert out["I1"]["preemptions"] > 0
    assert out["I4"]["preemptions"] > 0
    assert out["I1024"]["preemptions"] == 0
    assert out["I1024"]["optimal"] == out["I1024"]["pf"]
    save_json("fig13_csp", out)
    return out


if __name__ == "__main__":
    run()
