"""Shared benchmark utilities: calibrated cost models, table printing,
result persistence."""
from __future__ import annotations

import json
import os
import sys
from typing import Any, Dict, Iterable, List, Optional, Sequence

sys.path.insert(0, "src")

from repro.configs import get_config  # noqa: E402
from repro.core.cost_model import (TheoreticalCostModel,  # noqa: E402
                                   get_hardware)

OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "experiments/bench")

#: de-rating calibrated against the paper's measured gaps (Fig. 5-6):
#: matmuls reach ~60% of peak FLOPs, HBM streams ~75%, attention's
#: interleaved (non-overlapped) transfers reach only ~25% of bandwidth.
CALIB = dict(flops_eff=0.6, bw_eff=0.75, attn_bw_eff=0.25)


def cost_model(arch: str = "llama2-7b", hw: str = "a100",
               **overrides) -> TheoreticalCostModel:
    kw = dict(CALIB)
    kw.update(overrides)
    return TheoreticalCostModel(get_config(arch), get_hardware(hw), **kw)


def print_table(title: str, headers: Sequence[str],
                rows: Iterable[Sequence], fmt: Optional[str] = None) -> None:
    print(f"\n== {title} ==")
    widths = [max(len(str(h)), 10) for h in headers]
    rows = [["%.4g" % c if isinstance(c, float) else str(c) for c in r]
            for r in rows]
    for r in rows:
        for i, c in enumerate(r):
            widths[i] = max(widths[i], len(c))
    line = "  ".join(h.ljust(w) for h, w in zip(headers, widths))
    print(line)
    print("-" * len(line))
    for r in rows:
        print("  ".join(c.ljust(w) for c, w in zip(r, widths)))


def save_json(name: str, payload: Any) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=str)
    return path
