"""§Roofline — the per-(arch x shape) roofline table from the dry-run
artifacts (reads experiments/roofline/*.json written by
``python -m repro.launch.dryrun --all --unroll --out experiments/roofline``;
falls back to experiments/dryrun for cells not yet re-run unrolled).
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import print_table, save_json

DIRS = ("experiments/roofline", "experiments/dryrun")


def _advice(rep: dict) -> str:
    dom = rep["roofline"]["dominant"]
    shape = rep["shape"]
    if dom == "collective_s":
        return "cut FSDP all-gathers (replicate small params / overlap)"
    if dom == "memory_s":
        if "decode" in shape or "long" in shape:
            return "seq-shard KV wider / quantize KV to int8"
        return "fuse residual/norm streams; bf16 end-to-end"
    if rep["roofline"]["useful_flops_fraction"] < 0.5:
        return "remove redundant compute (remat policy / MoE dispatch)"
    return "compute-bound: already near the right wall"


def load_cells() -> dict:
    cells = {}
    for d in DIRS:
        for path in sorted(glob.glob(os.path.join(d, "*_sp.json"))):
            with open(path) as f:
                rep = json.load(f)
            key = (rep["arch"], rep["shape"])
            if key not in cells or rep.get("unroll"):
                if key in cells and cells[key].get("unroll") \
                        and not rep.get("unroll"):
                    continue
                cells[key] = rep
    return cells


def run() -> dict:
    cells = load_cells()
    if not cells:
        print("roofline_table: no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --all --unroll --out "
              "experiments/roofline` first")
        return {}
    rows = []
    out = {}
    for (arch, shape), rep in sorted(cells.items()):
        r = rep["roofline"]
        total = r["compute_s"] + r["memory_s"] + r["collective_s"]
        frac = max(r["compute_s"], r["memory_s"], r["collective_s"]) / total \
            if total else 0.0
        rows.append([arch, shape,
                     f"{r['compute_s']*1e3:.2f}",
                     f"{r['memory_s']*1e3:.2f}",
                     f"{r['collective_s']*1e3:.2f}",
                     r["dominant"].replace("_s", ""),
                     f"{r['useful_flops_fraction']:.1%}",
                     "Y" if rep.get("fits_hbm") else "N",
                     "Y" if rep.get("unroll") else "n",
                     _advice(rep)])
        out[f"{arch}|{shape}"] = {
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "useful": r["useful_flops_fraction"],
            "model_flops": r["model_flops"],
        }
    print_table("§Roofline — per (arch x shape), 16x16 mesh, TPU v5e "
                "(C/M/X in ms per step)",
                ["arch", "shape", "C(ms)", "M(ms)", "X(ms)", "dominant",
                 "useful", "fits", "unr", "next lever"], rows)
    save_json("roofline_table", out)
    return out


if __name__ == "__main__":
    run()
