"""fig_prefix_sharing — shared-prefix page reuse on the paged plane
(PR 4; Optimizing LLM Queries in Relational Workloads, arXiv 2403.05821).

Relational LLM workloads fan one system prompt / table schema out over
many rows: most of every prompt is the same tokens.  With pooled KV
pages and the refcounted prefix registry, requests whose prompts share
leading FULL pages map the SAME physical pages and skip their prefill
compute entirely.

This benchmark sweeps the duplicate-prefix fraction of a
``data.workloads.shared_prefix`` workload (8 requests per point, the
group's template request staggered one batch ahead so its pages are in
the registry — prefix reuse is cross-batch) and runs each point through
the paged engine with sharing ON and OFF.  Reported per point:

  * peak resident pages (block-table-referenced physical pages — shared
    pages count ONCE; the dedup signal),
  * wall tok/s (sharing skips the shared tokens' prefill FLOPs; both
    engines run ``share_jits=True`` + ``warmup()`` so compiles stay out
    of the timed window — PR 8),
  * the engine's phase timers (attach / prefill / upload seconds) — the
    wall-clock attribution of where zero-copy attach and the coalesced
    block-table/grid uploads pay off,
  * prefix hits / shared tokens from the allocator stats.

Asserted: outputs are token-identical with sharing on and off at every
point (reuse is a memory/compute optimization, never a semantic one),
and at a 75% duplicate fraction sharing holds measurably fewer resident
pages than unshared paging AND is strictly faster wall-clock (the
attach path replaces the shared tokens' prefill work with a registry
pointer bump).
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import print_table, save_json


def _run(cfg, params, cm, reqs, *, sharing):
    from repro.core import make_scheduler
    from repro.serving import Engine, EngineConfig

    sched = make_scheduler("vllm", 400, S=512, replacement="srf")
    eng = Engine(cfg, params, sched,
                 EngineConfig(nslots=8, cache_len=64, chunk=16,
                              plane="paged", page_size=8,
                              prefix_sharing=sharing, share_jits=True),
                 cost_model=cm)
    eng.warmup()                   # compiles land OUTSIDE the timed window
    t0 = time.perf_counter()
    res = eng.run(reqs)
    wall = time.perf_counter() - t0
    toks = sum(len(v) for v in res.outputs.values())
    return dict(outputs=res.outputs, wall_s=wall, tokens=toks,
                tps=toks / wall,
                peak_pages=max(b.pages_used for b in res.metrics.batches),
                prefix_hits=eng.allocator.stats["prefix_hits"],
                shared_tokens=eng.allocator.stats["prefix_shared_tokens"],
                preemptions=res.metrics.num_preemptions,
                **{k: round(v, 6) for k, v in res.phase_stats.items()})


def run(smoke: bool = False) -> dict:
    import jax

    from repro.configs import get_config
    from repro.core import TheoreticalCostModel, get_hardware
    from repro.data.workloads import shared_prefix
    from repro.models import model as M

    cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                              dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    cm = TheoreticalCostModel(cfg, get_hardware("tpu_v5e"))

    fracs = [0.0, 0.75] if smoke else [0.0, 0.25, 0.5, 0.75]
    n = 8
    rows, payload = [], {}
    for frac in fracs:
        # 48-token prompts: prefill (3 chunk rounds) carries enough of
        # the wall that attach savings clear run-to-run noise
        wl_kw = dict(n=n, input_len=48, prefix_frac=frac, output_len=6,
                     vocab=cfg.vocab_size, stagger=1e-6, seed=3)
        point = {}
        for sharing in (False, True):
            point[sharing] = _run(cfg, params, cm, shared_prefix(**wl_kw),
                                  sharing=sharing)
        off, on = point[False], point[True]
        assert on["outputs"] == off["outputs"], \
            f"prefix sharing changed tokens at frac={frac}"
        rows.append([f"{frac:.2f}",
                     off["peak_pages"], on["peak_pages"],
                     f"{off['tps']:.1f}", f"{on['tps']:.1f}",
                     f"{on['attach_s'] * 1e3:.1f}",
                     f"{on['prefill_s'] * 1e3:.1f}",
                     f"{on['upload_s'] * 1e3:.1f}",
                     on["prefix_hits"], on["shared_tokens"]])
        payload[f"frac_{frac}"] = {
            "unshared": {k: v for k, v in off.items() if k != "outputs"},
            "shared": {k: v for k, v in on.items() if k != "outputs"},
        }
    print_table(
        f"fig_prefix_sharing — resident pages & tok/s vs duplicate-prefix "
        f"fraction (paged plane, {n} requests, page_size=8)",
        ["dup frac", "pages (off)", "pages (on)", "tok/s (off)",
         "tok/s (on)", "attach ms", "prefill ms", "upload ms",
         "hits", "shared toks"], rows)

    # the point of the exercise: ≥8 requests sharing a 75% prefix hold
    # measurably fewer resident pages than unshared paging — and with
    # compiles out of the timed window (PR 8), sharing is also strictly
    # faster: attached pages skip their prefill rounds outright
    hi = payload[f"frac_{fracs[-1]}"]
    assert hi["shared"]["peak_pages"] < hi["unshared"]["peak_pages"], hi
    assert hi["shared"]["prefix_hits"] >= n - 1, hi
    assert hi["shared"]["wall_s"] < hi["unshared"]["wall_s"], hi
    # no duplicate prefix -> no CROSS-request sharing.  The radix trie
    # (PR 9) can still legitimately re-attach a recompute-preempted
    # request's own surviving cached run — a partial hit the old
    # exact-match registry missed — so hits at frac 0 are bounded by
    # preemption churn, not zero.
    lo = payload["frac_0.0"]
    assert lo["shared"]["prefix_hits"] <= lo["shared"]["preemptions"]
    print("tokens identical with sharing on/off: True")
    payload["shared_vs_unshared_tps_ratio"] = (hi["shared"]["tps"] /
                                               hi["unshared"]["tps"])
    save_json("fig_prefix_sharing", payload)
    return payload


if __name__ == "__main__":
    run()
