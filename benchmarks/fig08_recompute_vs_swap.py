"""Fig. 8 — KV (re)computation vs swap-in times over #KVs (§5.4).

'Recompute' here is the activation-cached K/V-projection rebuild the
paper measures (per-KV cost falls with N as the weight-load bias
amortizes); the full-refill prefill cost (what a preempted request pays)
is reported alongside for contrast.

A validation section then runs the REAL engine (reduced model) in
``preempt_mode="swap"`` and compares the measured host restore latency
per swap-in against the analytical ``swap_time`` the scheduler used, and
checks the restored schedule still produces recompute-identical tokens.
"""
from __future__ import annotations

from benchmarks.common import cost_model, print_table, save_json


def analytical() -> dict:
    out = {}
    for hw in ("a100", "h100"):
        cm = cost_model("llama2-7b", hw)
        rows = []
        turning = None
        for n in (1, 8, 32, 100, 512, 2048, 8192, 32768, 100_000):
            t_proj = cm.kv_projection_time(n)
            t_swap = cm.swap_time(n)
            t_full = cm.recompute_time(min(n, 100_000))
            winner = "swap" if t_swap < t_proj else "recompute"
            if turning is None and t_proj <= t_swap:
                turning = n
            rows.append([n, f"{t_proj*1e3:.3f}", f"{t_swap*1e3:.3f}",
                         f"{t_full*1e3:.3f}", winner,
                         f"{t_proj/n*1e6:.2f}us"])
        print_table(
            f"Fig 8 — recompute vs swap on {hw} "
            f"(turning point ~{turning} KVs; paper: small vs M=100K)",
            ["#KVs", "kv-proj recompute (ms)", "swap-in (ms)",
             "full refill (ms)", "winner", "per-KV"], rows)
        out[hw] = {"turning_point": turning}
        assert turning is not None and turning < 5_000
    return out


def engine_validation(n_requests: int = 8) -> dict:
    """Measured engine swap/restore vs the analytical model (the
    'validation column'): real JAX execution on a reduced model under
    memory pressure that forces swap preemptions."""
    import dataclasses

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core import (Request, TheoreticalCostModel, get_hardware,
                            make_scheduler)
    from repro.models import model as M
    from repro.serving import Engine, EngineConfig

    cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                              dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    cm = TheoreticalCostModel(cfg, get_hardware("tpu_v5e"))

    rs = np.random.RandomState(0)
    def workload():
        reqs = []
        for i in range(n_requests):
            I, O = int(rs.randint(8, 25)), int(rs.randint(3, 9))
            reqs.append(Request(rid=i, input_len=I, output_len=O,
                                arrival=0.0,
                                prompt=rs.randint(0, cfg.vocab_size,
                                                  size=I).tolist()))
        return reqs

    results = {}
    for mode in ("recompute", "swap"):
        rs = np.random.RandomState(0)      # identical workload per mode
        sched = make_scheduler("vllm", 60, S=128, replacement="srf",
                               preempt_mode=mode)
        # async_swap=False: this column validates the MEASURED host
        # transfer against the analytical swap_time — the async plane
        # would overlap (hide) the D2H copy and report dispatch+drain
        # residue instead of the transfer itself
        eng = Engine(cfg, params, sched,
                     EngineConfig(nslots=4, cache_len=64, chunk=16,
                                  async_swap=False),
                     cost_model=cm)
        results[mode] = eng.run(workload())

    st = results["swap"].swap_stats
    assert st["swap_ins"] == st["swap_outs"] > 0, st
    assert results["swap"].outputs == results["recompute"].outputs, \
        "swap restore changed generated tokens"

    meas_in = st["wall_in_s"] / st["swap_ins"]
    meas_out = st["wall_out_s"] / st["swap_outs"]
    mean_kv = st["kv_in"] / st["swap_ins"]
    model_in = cm.swap_time(int(round(mean_kv)))
    rows = [[int(st["swap_ins"]), f"{mean_kv:.1f}",
             f"{meas_in*1e3:.3f}", f"{meas_out*1e3:.3f}",
             f"{model_in*1e3:.4f}",
             f"{meas_in/model_in:.0f}x" if model_in else "n/a", "yes"]]
    print_table(
        "Fig 8 validation — engine swap restore, reduced tinyllama "
        "(measured = CPU host wall; model = tpu_v5e host link)",
        ["swap-ins", "mean KVs", "meas in (ms)", "meas out (ms)",
         "model in (ms)", "meas/model", "tokens match"], rows)
    return {
        "swap_ins": st["swap_ins"], "mean_kv": mean_kv,
        "measured_in_s": meas_in, "measured_out_s": meas_out,
        "model_in_s": model_in,
        "tokens_match": True,
    }


def run(smoke: bool = False) -> dict:
    out = analytical()
    out["engine_validation"] = engine_validation(
        n_requests=4 if smoke else 8)
    save_json("fig08_recompute_vs_swap", out)
    return out


if __name__ == "__main__":
    run()
