"""Fig. 8 — KV (re)computation vs swap-in times over #KVs (§5.4).

'Recompute' here is the activation-cached K/V-projection rebuild the
paper measures (per-KV cost falls with N as the weight-load bias
amortizes); the full-refill prefill cost (what a preempted request pays)
is reported alongside for contrast.
"""
from __future__ import annotations

from benchmarks.common import cost_model, print_table, save_json


def run() -> dict:
    out = {}
    for hw in ("a100", "h100"):
        cm = cost_model("llama2-7b", hw)
        rows = []
        turning = None
        for n in (1, 8, 32, 100, 512, 2048, 8192, 32768, 100_000):
            t_proj = cm.kv_projection_time(n)
            t_swap = cm.swap_time(n)
            t_full = cm.recompute_time(min(n, 100_000))
            winner = "swap" if t_swap < t_proj else "recompute"
            if turning is None and t_proj <= t_swap:
                turning = n
            rows.append([n, f"{t_proj*1e3:.3f}", f"{t_swap*1e3:.3f}",
                         f"{t_full*1e3:.3f}", winner,
                         f"{t_proj/n*1e6:.2f}us"])
        print_table(
            f"Fig 8 — recompute vs swap on {hw} "
            f"(turning point ~{turning} KVs; paper: small vs M=100K)",
            ["#KVs", "kv-proj recompute (ms)", "swap-in (ms)",
             "full refill (ms)", "winner", "per-KV"], rows)
        out[hw] = {"turning_point": turning}
        assert turning is not None and turning < 5_000
    save_json("fig08_recompute_vs_swap", out)
    return out


if __name__ == "__main__":
    run()
