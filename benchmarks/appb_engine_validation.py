"""App. B — real-inference-system validation: run the ACTUAL engine
(reduced model, real JAX execution) under NRF / SRF / PF and check

  * outputs are byte-identical across policies (standard techniques do
    not change inference outputs),
  * the simulator's virtual latency matches the engine's cost-model
    latency for the same schedule class (the paper: 6 % avg error),
  * SRF does not regress vs NRF on the engine either.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import print_table, save_json
from repro.configs import get_config
from repro.core import (Request, TheoreticalCostModel, get_hardware,
                        make_scheduler)
from repro.core.simulator import simulate
from repro.models import model as M
from repro.serving import Engine, EngineConfig


def workload(cfg, n=8, seed=0):
    rs = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        I, O = int(rs.randint(8, 28)), int(rs.randint(4, 10))
        prompt = rs.randint(0, cfg.vocab_size, size=I).tolist()
        reqs.append(Request(rid=i, input_len=I, output_len=O,
                            arrival=0.0, prompt=prompt))   # offline burst
    return reqs


def run() -> dict:
    cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                              dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    cm = TheoreticalCostModel(cfg, get_hardware("tpu_v5e"))
    M_kv, S = 70, 128            # tight cache -> preemptions exercised

    rows = []
    out = {}
    outputs = {}
    for name, repl in (("vllm", "nrf"), ("vllm", "srf"), ("vllm_pf", "pf")):
        sched = make_scheduler(name, M_kv, S=S, replacement=repl)
        eng = Engine(cfg, params, sched,
                     EngineConfig(nslots=4, cache_len=64, chunk=16),
                     cost_model=cm)
        res = eng.run(workload(cfg))
        s = res.metrics.summary()
        outputs[repl] = res.outputs
        # simulator on the same workload/scheduler (no real execution)
        sim_sched = make_scheduler(name, M_kv, S=S, replacement=repl)
        sim_sched.cfg.max_running = 4
        sim = simulate(sim_sched, workload(cfg), cm)
        err = abs(sim.latency - s["latency"]) / max(s["latency"], 1e-12)
        key = f"{name}_{repl}"
        out[key] = dict(engine_latency=s["latency"], sim_latency=sim.latency,
                        rel_err=err, preemptions=s["preemptions"])
        rows.append([name, repl, f"{s['latency']*1e3:.3f}",
                     f"{sim.latency*1e3:.3f}", f"{err:.1%}",
                     int(s["preemptions"])])
    print_table("App. B — engine vs simulator (reduced tinyllama, real "
                "execution)",
                ["scheduler", "replacement", "engine lat (ms)",
                 "sim lat (ms)", "rel err", "preempt"], rows)
    # identical outputs across all policies
    for rid in outputs["nrf"]:
        assert outputs["nrf"][rid] == outputs["srf"][rid] == \
            outputs["pf"][rid], rid
    print("outputs byte-identical across NRF/SRF/PF: True")
    # simulator fidelity (paper: 6% avg / 12% max)
    assert all(d["rel_err"] < 0.12 for d in out.values())
    # SRF no-regression on the real engine, with real preemptions
    assert out["vllm_srf"]["preemptions"] > 0
    assert (out["vllm_srf"]["engine_latency"]
            <= out["vllm_nrf"]["engine_latency"] * 1.02)
    save_json("appb_engine_validation", out)
    return out


if __name__ == "__main__":
    run()
