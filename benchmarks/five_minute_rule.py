"""§6 — the five-minute rule for LLM inference: break-even residency
intervals per request length (paper: [0.33 s, 130 s] on H100, M=100K)."""
from __future__ import annotations

from benchmarks.common import cost_model, print_table, save_json
from repro.core.five_minute_rule import break_even_table


def run() -> dict:
    out = {}
    for hw in ("h100", "a100", "tpu_v5e"):
        cm = cost_model("llama2-7b", hw)
        table = break_even_table(cm, M=100_000,
                                 ns=(1, 8, 64, 512, 4095, 32768))
        rows = [[b.n_kvs, f"{b.per_kv*1e6:.2f}us", f"{b.interval:.2f}",
                 f"{b.interval_swap:.2f}"] for b in table]
        print_table(f"§6 five-minute rule on {hw} (M=100K)",
                    ["#KVs (N)", "t_recom/N", "break-even (s)",
                     "swap-based (s)"], rows)
        out[hw] = {b.n_kvs: b.interval for b in table}
        ivals = [b.interval for b in table]
        # non-increasing overall; strictly falling while the weight-load
        # bias amortizes (it saturates at the per-KV floor — paper: 3.3us)
        assert all(a >= b - 1e-9 for a, b in zip(ivals, ivals[1:]))
        assert ivals[0] > ivals[1] > ivals[2]
    # paper's H100 range: [0.33, 130] s between N=4095 and N=1
    h = out["h100"]
    assert 0.02 < h[4095] < 15.0
    assert 5.0 < h[1] < 2000.0
    save_json("five_minute_rule", out)
    return out


if __name__ == "__main__":
    run()
