"""``python -m benchmarks.run`` — every paper table/figure, in order.

Each module prints its table, asserts the paper's qualitative claims,
and persists JSON under experiments/bench/.
"""
from __future__ import annotations

import sys
import time
import traceback

sys.path.insert(0, "src")

from benchmarks import (appa_low_contention, appb_engine_validation,  # noqa: E402
                        appc_ranking, fig04_cost_linearity, fig06_roofline,
                        fig07_slo_pareto, fig08_recompute_vs_swap,
                        fig09_schedulers, fig11_preemption_free,
                        fig12_vary_m, fig13_csp, fig14_srf,
                        five_minute_rule, roofline_table)

MODULES = [
    ("Fig 4  cost-model linearity", fig04_cost_linearity),
    ("Fig 5/6 roofline placement", fig06_roofline),
    ("Fig 7  SLO pareto", fig07_slo_pareto),
    ("Fig 8  recompute vs swap", fig08_recompute_vs_swap),
    ("Fig 9  scheduler comparison (W=1024)", fig09_schedulers),
    ("App A  low contention (W=32)", appa_low_contention),
    ("Fig 11 preemption-free", fig11_preemption_free),
    ("Fig 12 varying M", fig12_vary_m),
    ("Fig 13 CSP optimal scheduling", fig13_csp),
    ("Fig 14 SRF vs NRF", fig14_srf),
    ("App B  engine-vs-sim validation", appb_engine_validation),
    ("App C  heterogeneous ranking", appc_ranking),
    ("$6     five-minute rule", five_minute_rule),
    ("$Roofline table (dry-run artifacts)", roofline_table),
]


def main() -> int:
    t0 = time.time()
    failures = []
    for name, mod in MODULES:
        print(f"\n{'='*72}\n>> {name}\n{'='*72}")
        t = time.time()
        try:
            mod.run()
            print(f"[ok] {name} ({time.time()-t:.1f}s)")
        except Exception:
            failures.append(name)
            traceback.print_exc()
            print(f"[FAIL] {name}")
    print(f"\n{'='*72}")
    print(f"benchmarks: {len(MODULES)-len(failures)}/{len(MODULES)} passed "
          f"in {time.time()-t0:.0f}s")
    if failures:
        print("failed:", ", ".join(failures))
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
