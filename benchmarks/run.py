"""``python -m benchmarks.run`` — every paper table/figure, in order.

Each module prints its table, asserts the paper's qualitative claims,
and persists JSON under experiments/bench/.

``--smoke`` runs the same modules with tiny workload sizes (small W/n)
so one offline command catches schedule/benchmark regressions in
minutes; the qualitative assertions still run.  Positional arguments
filter modules by substring (e.g. ``python -m benchmarks.run fig08``).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

sys.path.insert(0, "src")

from benchmarks import (appa_low_contention, appb_engine_validation,  # noqa: E402
                        appc_ranking, fig04_cost_linearity, fig06_roofline,
                        fig07_slo_pareto, fig08_recompute_vs_swap,
                        fig09_schedulers, fig11_preemption_free,
                        fig12_vary_m, fig13_csp, fig14_srf,
                        fig_cache_replacement, fig_engine_wall,
                        fig_fault_recovery, fig_prefix_sharing,
                        fig_radix_trie, five_minute_rule, roofline_table)

# (name, module, smoke-mode kwargs).  Modules without a size knob are
# already tiny/analytical and run unchanged in smoke mode.
MODULES = [
    ("Fig 4  cost-model linearity", fig04_cost_linearity, {}),
    ("Fig 5/6 roofline placement", fig06_roofline, {}),
    ("Fig 7  SLO pareto", fig07_slo_pareto, {}),
    ("Fig 8  recompute vs swap", fig08_recompute_vs_swap, {"smoke": True}),
    ("Fig 9  scheduler comparison (W=1024)", fig09_schedulers, {"W": 128}),
    ("App A  low contention (W=32)", appa_low_contention, {}),
    ("Fig 11 preemption-free", fig11_preemption_free, {"W": 256}),
    ("Fig 12 varying M", fig12_vary_m, {"W": 256}),
    ("Fig 13 CSP optimal scheduling", fig13_csp, {}),
    ("Fig 14 SRF vs NRF", fig14_srf, {"n": 128}),
    ("App B  engine-vs-sim validation", appb_engine_validation, {}),
    ("$Perf  engine wall-time planes", fig_engine_wall, {"smoke": True}),
    ("$Perf  shared-prefix page reuse", fig_prefix_sharing, {"smoke": True}),
    ("$Trie  radix vs exact prefix lookup", fig_radix_trie,
     {"smoke": True}),
    ("$6/§8  cache replacement + demotion", fig_cache_replacement,
     {"smoke": True}),
    ("App C  heterogeneous ranking", appc_ranking, {"W": 96}),
    ("$6     five-minute rule", five_minute_rule, {}),
    ("$Chaos fault injection & recovery ladder", fig_fault_recovery,
     {"smoke": True}),
    ("$Roofline table (dry-run artifacts)", roofline_table, {}),
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.run")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload sizes (fast offline regression "
                         "check)")
    ap.add_argument("filters", nargs="*",
                    help="only run modules whose name contains a filter")
    args = ap.parse_args(argv)

    t0 = time.time()
    failures = []
    records = []
    payloads = {}
    ran = 0
    for name, mod, smoke_kw in MODULES:
        if args.filters and not any(f.lower() in name.lower()
                                    or f.lower() in mod.__name__.lower()
                                    for f in args.filters):
            continue
        ran += 1
        print(f"\n{'='*72}\n>> {name}\n{'='*72}")
        t = time.time()
        try:
            payloads[mod.__name__] = mod.run(
                **(smoke_kw if args.smoke else {}))
            status = "ok"
            print(f"[ok] {name} ({time.time()-t:.1f}s)")
        except Exception:
            status = "fail"
            failures.append(name)
            traceback.print_exc()
            print(f"[FAIL] {name}")
        records.append({"name": name, "module": mod.__name__,
                        "status": status,
                        "duration_s": round(time.time() - t, 2)})
    print(f"\n{'='*72}")
    mode = "smoke" if args.smoke else "full"
    print(f"benchmarks ({mode}): {ran-len(failures)}/{ran} passed "
          f"in {time.time()-t0:.0f}s")
    if args.smoke and not args.filters:
        # one consolidated artifact for the smoke gate: CI/check.sh can
        # diff module-level status and spot pathological slowdowns
        # without parsing per-figure JSONs
        from benchmarks.common import save_json
        save_json("BENCH_smoke", {
            "mode": mode,
            "passed": ran - len(failures),
            "ran": ran,
            "failed": failures,
            "total_s": round(time.time() - t0, 2),
            "modules": records,
        })
    wall = payloads.get("benchmarks.fig_engine_wall")
    share = payloads.get("benchmarks.fig_prefix_sharing")
    if args.smoke and wall and share:
        # repo-root perf headline (PR 8): the two ratios the paged
        # plane is accountable for — check.sh gates on the first
        import json
        bench8 = {
            "paged_vs_batched_tps_ratio":
                round(wall["paged_vs_batched_tps_ratio"], 4),
            "shared_vs_unshared_tps_ratio":
                round(share["shared_vs_unshared_tps_ratio"], 4),
            "paged_tps": round(wall["paged"]["tps"], 2),
            "batched_tps": round(wall["batched"]["tps"], 2),
        }
        with open("BENCH_8.json", "w") as f:
            json.dump(bench8, f, indent=1)
        print("BENCH_8.json:", bench8)
    trie = payloads.get("benchmarks.fig_radix_trie")
    if args.smoke and trie:
        # repo-root trie headline (PR 9): what partial-prefix matching
        # buys over exact-match lookup on branching conversations —
        # check.sh gates on the shared-tokens ratio
        import json
        bench9 = {
            "trie_vs_exact_shared_tokens_ratio":
                round(trie["trie_vs_exact_shared_tokens_ratio"], 4),
            "trie_vs_exact_tps_ratio":
                round(trie["trie_vs_exact_tps_ratio"], 4),
            "conversation_tree_partial_hit_tokens":
                trie["conversation_tree"]["trie"]["partial_hit_tokens"],
        }
        with open("BENCH_9.json", "w") as f:
            json.dump(bench9, f, indent=1)
        print("BENCH_9.json:", bench9)
    if failures:
        print("failed:", ", ".join(failures))
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
